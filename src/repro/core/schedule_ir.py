"""Compiled structure-of-arrays IR for round-based schedules.

The legacy :mod:`repro.core.schedule` representation materializes every
message as a frozen ``Msg`` dataclass; at paper scale (p = 36*32 = 1152) the
O(p^2)-message alltoall families allocate >1M Python objects per schedule and
dominate both generation and simulation time.  This module is the compiled
counterpart: a :class:`CompiledSchedule` stores one flat numpy array per
message field (``src``, ``dst``, ``elems``) plus a CSR-style ``round_ptr``
delimiting rounds, and the simulator reduces over these arrays with
``np.bincount`` instead of per-message Python dict updates.

Two entry points produce the IR:

* :func:`compile_schedule` flattens any legacy ``Schedule`` (every generator
  keeps working unchanged);
* the ``*_ir`` array-native generators build the O(p^2) alltoall families
  (``kported``, ``bruck``, ``klane``, ``fulllane``) directly as arrays and
  never construct a single ``Msg``.  They are round-for-round,
  message-multiset-identical to their legacy counterparts (pinned by
  ``tests/test_schedule_ir.py``) — including the per-message block CSR.

The IR is the *compile* stage of the schedule pipeline

    generate (core.schedule) -> compile (here) -> optimize (core.passes)
                             -> validate (core.validate) -> simulate

``compiled_schedule(..., optimize="lane"|"ported")`` hands the cached IR to
the optimizer's round-compaction pipeline and caches the (oracle-validated)
rewrite under its own key.

Block-metadata ownership rules
------------------------------
The IR carries per-message abstract block ids in **CSR form**:
``blk_ptr[i]:blk_ptr[i+1]`` delimits message ``i``'s slice of ``blk_ids``
(ids sorted ascending within a message — the canonical order, matching the
legacy ``tuple(sorted(blocks))`` convention).  Block metadata is what makes
a schedule *checkable*: the array-native validity oracle
(:mod:`repro.core.validate`) replays data-flow over these arrays with two
sorts instead of per-message set updates, and the optimizer passes
(:mod:`repro.core.passes`) consult them to keep round merges causally
legal.  Rules:

* the ``*_ir`` generators always attach blocks (array-natively — no Msg
  objects); ``compile_schedule(..., with_blocks=True)`` flattens legacy
  ``Msg.blocks`` into the same canonical form;
* ``compile_schedule`` without ``with_blocks`` still drops the metadata
  (cheapest path when only the cost model is needed); schedules without
  blocks cannot be validated or safely rewritten — ``validate`` and the
  compaction pass refuse them rather than trust them;
* ppermute compilation in ``core.collectives`` remains on the legacy
  ``Msg`` path (it needs per-message python tuples anyway).

Topology-dependent per-round statistics (node classification of each
message) are cached on the compiled schedule per ``procs_per_node``, so
re-simulating the same structure under several machine models — or, via the
schedule cache, at several payload sizes — never re-derives them.

Process-wide schedule cache
---------------------------
:func:`compiled_schedule` memoizes compiled schedules keyed by
``(op, algorithm, topo, k, c, root)``.  Round structure is independent of
the per-block payload ``c`` (only ``elems`` scales with it), which the
cost-model selector exploits by simulating two payload sizes and
interpolating the affine ``A + B*c`` round cost (see
``core.selector.affine_cost``).
"""

from __future__ import annotations

import dataclasses
import threading
from typing import Callable

import numpy as np

from repro.core import schedule as sched
from repro.core.topology import Topology
from repro.obs import metrics as obs_metrics
from repro.obs.trace import TRACER

__all__ = [
    "CompiledSchedule",
    "RoundStats",
    "compile_schedule",
    "segmented_arange",
    "gather_block_csr",
    "split_messages",
    "merge_messages",
    "relay_messages",
    "kported_alltoall_ir",
    "bruck_alltoall_ir",
    "klane_alltoall_ir",
    "fulllane_alltoall_ir",
    "IR_GENERATORS",
    "compiled_schedule",
    "schedule_cache_info",
    "schedule_cache_clear",
    "schedule_cache_reset",
    "cache_export",
    "cache_seed",
]


@dataclasses.dataclass(frozen=True)
class RoundStats:
    """Per-(round, proc) and per-(round, node) aggregates for one
    ``procs_per_node`` partitioning of a compiled schedule.

    All 2-D arrays are dense ``[R, p]`` or ``[R, N]`` float64/int64 grids;
    entries for (round, proc/node) pairs with no traffic are zero and masked
    by the corresponding ``*_cnt > 0`` test (matching the legacy simulator,
    which only iterates over dict keys that were touched).
    """

    send_elems: np.ndarray  # [R, p] float64 (exact: integer-valued < 2^53)
    send_cnt: np.ndarray  # [R, p] int64
    send_inter: np.ndarray  # [R, p] bool — proc had >= 1 off-node send
    recv_elems: np.ndarray  # [R, p] float64
    recv_cnt: np.ndarray  # [R, p] int64
    recv_inter: np.ndarray  # [R, p] bool
    node_out: np.ndarray  # [R, N] float64, off-node elems leaving
    node_in: np.ndarray  # [R, N] float64
    node_out_msgs: np.ndarray  # [R, N] int64
    node_in_msgs: np.ndarray  # [R, N] int64
    node_intra: np.ndarray  # [R, N] float64
    node_intra_cnt: np.ndarray  # [R, N] int64
    inter_elems: int  # total off-node traffic
    intra_elems: int  # total on-node traffic


@dataclasses.dataclass(frozen=True)
class CompiledSchedule:
    """Structure-of-arrays schedule: flat message arrays + round offsets.

    ``round_ptr`` has length ``num_rounds + 1``; round ``r`` owns messages
    ``round_ptr[r]:round_ptr[r+1]`` (possibly empty, preserving the legacy
    round count for ``SimResult.rounds`` parity).
    """

    op: str
    algorithm: str
    p: int
    k: int
    src: np.ndarray  # int64 [M]
    dst: np.ndarray  # int64 [M]
    elems: np.ndarray  # int64 [M]
    round_ptr: np.ndarray  # int64 [R+1]
    # optional CSR block metadata: message i carries blk_ids[blk_ptr[i]:
    # blk_ptr[i+1]] (sorted ascending within the message).  None on
    # schedules compiled without blocks; required by validate/passes.
    blk_ptr: np.ndarray | None = None  # int64 [M+1]
    blk_ids: np.ndarray | None = None  # int64 [sum(nblocks)]
    # per-procs_per_node derived statistics (lazily built, shared across
    # simulations of the same structure under different cost params).
    _stats: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False
    )

    @property
    def has_blocks(self) -> bool:
        return self.blk_ptr is not None and self.blk_ids is not None

    @property
    def num_rounds(self) -> int:
        return len(self.round_ptr) - 1

    @property
    def num_msgs(self) -> int:
        return int(self.src.size)

    def total_elems(self) -> int:
        return int(self.elems.sum())

    def round_ids(self) -> np.ndarray:
        """Round index of each message (``[M]`` int64)."""
        return np.repeat(
            np.arange(self.num_rounds, dtype=np.int64), np.diff(self.round_ptr)
        )

    def node_of(self, procs_per_node: int) -> tuple[np.ndarray, np.ndarray]:
        """(src_node, dst_node) arrays under a node partitioning."""
        return self.src // procs_per_node, self.dst // procs_per_node

    def max_port_width(self) -> int:
        """Max concurrent sends or receives at any processor in any round
        (parity with ``Schedule.max_port_width``)."""
        if self.num_msgs == 0:
            return 0
        rid = self.round_ids()
        skey = rid * self.p + self.src
        dkey = rid * self.p + self.dst
        n = self.num_rounds * self.p
        return int(
            max(
                np.bincount(skey, minlength=n).max(),
                np.bincount(dkey, minlength=n).max(),
            )
        )

    def stats(self, procs_per_node: int) -> RoundStats:
        """Aggregate per-round statistics under a node partitioning; cached
        per ``procs_per_node`` so repeated simulation shares the work."""
        cached = self._stats.get(procs_per_node)
        if cached is not None:
            return cached
        n = procs_per_node
        p, R = self.p, self.num_rounds
        if p % n:
            raise ValueError(f"p={p} not divisible by procs_per_node={n}")
        N = p // n
        rid = self.round_ids()
        snode = self.src // n
        dnode = self.dst // n
        inter = snode != dnode
        ew = self.elems.astype(np.float64)

        skey = rid * p + self.src
        dkey = rid * p + self.dst
        pm = R * p
        send_elems = np.bincount(skey, weights=ew, minlength=pm).reshape(R, p)
        send_cnt = np.bincount(skey, minlength=pm).reshape(R, p)
        send_inter = (
            np.bincount(skey[inter], minlength=pm).reshape(R, p) > 0
        )
        recv_elems = np.bincount(dkey, weights=ew, minlength=pm).reshape(R, p)
        recv_cnt = np.bincount(dkey, minlength=pm).reshape(R, p)
        recv_inter = (
            np.bincount(dkey[inter], minlength=pm).reshape(R, p) > 0
        )

        nskey = rid * N + snode
        ndkey = rid * N + dnode
        nm = R * N
        node_out = np.bincount(
            nskey[inter], weights=ew[inter], minlength=nm
        ).reshape(R, N)
        node_in = np.bincount(
            ndkey[inter], weights=ew[inter], minlength=nm
        ).reshape(R, N)
        node_out_msgs = np.bincount(nskey[inter], minlength=nm).reshape(R, N)
        node_in_msgs = np.bincount(ndkey[inter], minlength=nm).reshape(R, N)
        node_intra = np.bincount(
            nskey[~inter], weights=ew[~inter], minlength=nm
        ).reshape(R, N)
        node_intra_cnt = np.bincount(nskey[~inter], minlength=nm).reshape(R, N)

        st = RoundStats(
            send_elems=send_elems,
            send_cnt=send_cnt.astype(np.int64),
            send_inter=send_inter,
            recv_elems=recv_elems,
            recv_cnt=recv_cnt.astype(np.int64),
            recv_inter=recv_inter,
            node_out=node_out,
            node_in=node_in,
            node_out_msgs=node_out_msgs.astype(np.int64),
            node_in_msgs=node_in_msgs.astype(np.int64),
            node_intra=node_intra,
            node_intra_cnt=node_intra_cnt.astype(np.int64),
            inter_elems=int(self.elems[inter].sum()),
            intra_elems=int(self.elems[~inter].sum()),
        )
        self._stats[procs_per_node] = st
        return st


# ---------------------------------------------------------------------------
# Compilation from the legacy Msg representation.
# ---------------------------------------------------------------------------


def compile_schedule(
    schedule: sched.Schedule, *, with_blocks: bool = False
) -> CompiledSchedule:
    """Flatten a legacy ``Schedule`` into the array IR.

    ``with_blocks=True`` additionally flattens every ``Msg.blocks`` tuple
    into the CSR block arrays (sorted ascending per message), making the
    result consumable by the validity oracle and the optimizer passes.
    """
    counts = [len(r.msgs) for r in schedule.rounds]
    m = sum(counts)
    src = np.empty(m, dtype=np.int64)
    dst = np.empty(m, dtype=np.int64)
    elems = np.empty(m, dtype=np.int64)
    nblk = np.empty(m, dtype=np.int64) if with_blocks else None
    blk_chunks: list = []
    i = 0
    for r in schedule.rounds:
        for msg in r.msgs:
            src[i] = msg.src
            dst[i] = msg.dst
            elems[i] = msg.elems
            if with_blocks:
                nblk[i] = len(msg.blocks)
                blk_chunks.append(sorted(msg.blocks))
            i += 1
    round_ptr = np.zeros(len(counts) + 1, dtype=np.int64)
    np.cumsum(counts, out=round_ptr[1:])
    blk_ptr = blk_ids = None
    if with_blocks:
        blk_ptr = np.zeros(m + 1, dtype=np.int64)
        np.cumsum(nblk, out=blk_ptr[1:])
        blk_ids = (
            np.concatenate([np.asarray(b, dtype=np.int64) for b in blk_chunks])
            if blk_chunks
            else np.empty(0, dtype=np.int64)
        )
    return CompiledSchedule(
        op=schedule.op,
        algorithm=schedule.algorithm,
        p=schedule.p,
        k=schedule.k,
        src=src,
        dst=dst,
        elems=elems,
        round_ptr=round_ptr,
        blk_ptr=blk_ptr,
        blk_ids=blk_ids,
    )


# ---------------------------------------------------------------------------
# Message split / merge primitives (array surgery on the CSR block arrays).
# These are the payload-rewrite building blocks of the optimizer passes:
# ``SplitPayloads`` splits via :func:`split_messages`, ``CoalesceMessages``
# fuses via :func:`merge_messages`, and the two are (multiset-)inverses, so
# the validity oracle sees bit-identical block delivery either way.
# ---------------------------------------------------------------------------


def segmented_arange(counts: np.ndarray) -> np.ndarray:
    """``concatenate([arange(c) for c in counts])`` without the Python loop:
    the within-segment offset of every element of a ragged array described
    by per-segment ``counts``.  The CSR-surgery workhorse shared by the
    block-gather/split primitives here and the optimizer passes."""
    counts = np.asarray(counts, dtype=np.int64)
    total = int(counts.sum())
    return np.arange(total, dtype=np.int64) - np.repeat(
        np.cumsum(counts) - counts, counts
    )


def gather_block_csr(
    blk_ptr: np.ndarray, blk_ids: np.ndarray, order: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Reorder a CSR block array by a message permutation ``order``:
    returns ``(new_blk_ptr, new_blk_ids)`` with message ``i``'s blocks taken
    from old message ``order[i]``, slices concatenated in the new order."""
    nblk = np.diff(blk_ptr)
    g_counts = nblk[order]
    base = np.repeat(blk_ptr[:-1][order], g_counts)
    off = segmented_arange(g_counts)
    new_ptr = np.zeros(order.size + 1, dtype=np.int64)
    np.cumsum(g_counts, out=new_ptr[1:])
    return new_ptr, blk_ids[base + off]


def split_messages(
    cs: CompiledSchedule, factors: np.ndarray
) -> CompiledSchedule:
    """Split message ``i`` into ``factors[i]`` parallel same-round parts.

    Each part keeps the original ``(src, dst)`` and lands in the original
    round, directly after its siblings; ``elems`` is divided as evenly as
    possible (every part nonempty — factors are clamped to ``elems``) and
    the message's block slice is *partitioned* contiguously across the
    parts (parts beyond the block count carry zero blocks).  Because the
    parts partition both the payload and the block set, the per-round
    (src, dst, blk) hop multiset — what the validity oracle replays — is
    exactly that of the input, and :func:`merge_messages` is an inverse up
    to message order within a round.
    """
    factors = np.asarray(factors, dtype=np.int64)
    if factors.shape != (cs.num_msgs,):
        raise ValueError(
            f"factors must have shape ({cs.num_msgs},), got {factors.shape}"
        )
    if cs.num_msgs == 0:
        return cs
    f = np.clip(factors, 1, np.maximum(cs.elems, 1))
    if int(f.max()) <= 1:
        return cs
    total = int(f.sum())
    mid = np.repeat(np.arange(cs.num_msgs, dtype=np.int64), f)
    part = segmented_arange(f)
    base, rem = cs.elems // f, cs.elems % f
    new_elems = base[mid] + (part < rem[mid])
    new_ptr = np.zeros(cs.num_rounds + 1, dtype=np.int64)
    np.cumsum(
        np.bincount(cs.round_ids(), weights=f.astype(np.float64),
                    minlength=cs.num_rounds).astype(np.int64),
        out=new_ptr[1:],
    )
    blk_ptr = blk_ids = None
    if cs.has_blocks:
        nblk = np.diff(cs.blk_ptr)
        bbase, brem = nblk // f, nblk % f
        part_counts = bbase[mid] + (part < brem[mid])
        blk_ptr = np.zeros(total + 1, dtype=np.int64)
        np.cumsum(part_counts, out=blk_ptr[1:])
        # contiguous in-order partition: the flat block array is unchanged
        blk_ids = cs.blk_ids
    return dataclasses.replace(
        cs,
        src=cs.src[mid],
        dst=cs.dst[mid],
        elems=new_elems,
        round_ptr=new_ptr,
        blk_ptr=blk_ptr,
        blk_ids=blk_ids,
        _stats={},
    )


def merge_messages(cs: CompiledSchedule) -> CompiledSchedule:
    """Fuse same-``(round, src, dst)`` messages into one message with the
    summed element count and the concatenated (canonically re-sorted) block
    set.  Returns ``cs`` itself when there is nothing to fuse."""
    if cs.num_msgs == 0:
        return cs
    p = cs.p
    rid = cs.round_ids()
    key = (rid * p + cs.src) * p + cs.dst
    order = np.argsort(key, kind="stable")
    sk = key[order]
    first = np.ones(sk.size, dtype=bool)
    first[1:] = sk[1:] != sk[:-1]
    starts = np.flatnonzero(first)
    if starts.size == cs.num_msgs:
        return cs  # nothing to fuse
    new_src = cs.src[order][starts]
    new_dst = cs.dst[order][starts]
    new_rid = rid[order][starts]
    new_elems = np.add.reduceat(cs.elems[order], starts)
    new_ptr = np.zeros(cs.num_rounds + 1, dtype=np.int64)
    np.cumsum(
        np.bincount(new_rid, minlength=cs.num_rounds), out=new_ptr[1:]
    )
    blk_ptr = blk_ids = None
    if cs.has_blocks:
        gptr, flat = gather_block_csr(cs.blk_ptr, cs.blk_ids, order)
        fused_counts = np.add.reduceat(np.diff(gptr), starts)
        seg_id = np.repeat(
            np.arange(fused_counts.size, dtype=np.int64), fused_counts
        )
        flat = flat[np.lexsort((flat, seg_id))]  # canonical: ascending/msg
        blk_ptr = np.zeros(fused_counts.size + 1, dtype=np.int64)
        np.cumsum(fused_counts, out=blk_ptr[1:])
        blk_ids = flat
    return dataclasses.replace(
        cs,
        src=new_src,
        dst=new_dst,
        elems=new_elems,
        round_ptr=new_ptr,
        blk_ptr=blk_ptr,
        blk_ids=blk_ids,
        _stats={},
    )


def relay_messages(
    cs: CompiledSchedule,
    via_src: np.ndarray,
    via_dst: np.ndarray,
) -> CompiledSchedule:
    """Reroute messages through relay ranks — the fault-repair remap
    primitive (ISSUE 6).

    ``via_src[i] >= 0`` stages message ``i`` out through a relay: the hop
    ``src -> via_src`` is emitted in a *stage-out* round directly before
    message ``i``'s original round, and the main hop departs from
    ``via_src``.  ``via_dst[i] >= 0`` symmetrically stages it in: the main
    hop lands at ``via_dst`` and a *stage-in* hop ``via_dst -> dst`` is
    emitted directly after the original round.  ``-1`` leaves that side
    untouched.  Every hop carries the message's full payload and block
    slice, so the relayed schedule delivers bit-identical block semantics:

    * stage-out precedes the main hop, so the relay holds the blocks
      strictly before forwarding them (the oracle's causality rule);
    * stage-in follows the main hop but still precedes every later
      original round, so downstream consumers at ``dst`` keep their
      acquisition-before-requirement ordering.

    Rounds are interleaved per original round — ``[stage-out, original,
    stage-in]`` — and a stage round is only materialized when some message
    needs it, so un-relayed regions keep their round structure (and empty
    original rounds are preserved for round-count parity).  The intended
    use is routing off-node traffic around dead network ports: the relay
    hops are *intra-node* (``core.passes.RepairSchedule`` picks surviving
    local ranks), so repair never creates new off-node traffic.
    """
    via_src = np.asarray(via_src, dtype=np.int64)
    via_dst = np.asarray(via_dst, dtype=np.int64)
    if via_src.shape != (cs.num_msgs,) or via_dst.shape != (cs.num_msgs,):
        raise ValueError(
            f"via_src/via_dst must have shape ({cs.num_msgs},), got "
            f"{via_src.shape}/{via_dst.shape}"
        )
    out = via_src >= 0
    inn = via_dst >= 0
    if not out.any() and not inn.any():
        return cs
    if (via_src[out] == cs.src[out]).any() or (
        via_dst[inn] == cs.dst[inn]
    ).any():
        raise ValueError("a message cannot relay through its own endpoint")
    R = cs.num_rounds
    reps = 1 + out.astype(np.int64) + inn.astype(np.int64)
    mid = np.repeat(np.arange(cs.num_msgs, dtype=np.int64), reps)
    pos = segmented_arange(reps)
    # phase 0 = stage-out, 1 = main, 2 = stage-in (per original round)
    phase = pos + (~out).astype(np.int64)[mid]
    main_src = np.where(out, via_src, cs.src)
    main_dst = np.where(inn, via_dst, cs.dst)
    hop_src = np.select(
        [phase == 0, phase == 1],
        [cs.src[mid], main_src[mid]],
        default=via_dst[mid],
    )
    hop_dst = np.select(
        [phase == 0, phase == 1],
        [via_src[mid], main_dst[mid]],
        default=cs.dst[mid],
    )
    rid = cs.round_ids()[mid]
    keys = rid * 3 + phase
    # materialize used stage slots; keep every original round (even empty)
    all_keys = np.union1d(keys, np.arange(R, dtype=np.int64) * 3 + 1)
    new_rid = np.searchsorted(all_keys, keys)
    order = np.argsort(new_rid, kind="stable")
    new_ptr = np.zeros(all_keys.size + 1, dtype=np.int64)
    np.cumsum(np.bincount(new_rid, minlength=all_keys.size), out=new_ptr[1:])
    blk_ptr = blk_ids = None
    if cs.has_blocks:
        blk_ptr, blk_ids = gather_block_csr(cs.blk_ptr, cs.blk_ids, mid[order])
    return dataclasses.replace(
        cs,
        src=hop_src[order],
        dst=hop_dst[order],
        elems=cs.elems[mid][order],
        round_ptr=new_ptr,
        blk_ptr=blk_ptr,
        blk_ids=blk_ids,
        _stats={},
    )


def _from_rounds(
    op: str,
    algorithm: str,
    p: int,
    k: int,
    rounds: list[tuple],
    blocks: list[tuple] | None = None,
) -> CompiledSchedule:
    """Assemble a CompiledSchedule from per-round (src, dst, elems) triples.

    ``blocks`` (parallel to ``rounds``) holds per-round ``(counts, flat)``
    pairs: ``counts[i]`` block ids per message, concatenated in ``flat``.
    """
    if rounds:
        src = np.concatenate([r[0] for r in rounds])
        dst = np.concatenate([r[1] for r in rounds])
        elems = np.concatenate([r[2] for r in rounds])
    else:
        src = dst = elems = np.empty(0, dtype=np.int64)
    round_ptr = np.zeros(len(rounds) + 1, dtype=np.int64)
    np.cumsum([r[0].size for r in rounds], out=round_ptr[1:])
    blk_ptr = blk_ids = None
    if blocks is not None:
        counts = (
            np.concatenate([b[0] for b in blocks])
            if blocks
            else np.empty(0, dtype=np.int64)
        )
        blk_ids = (
            np.concatenate([b[1] for b in blocks])
            if blocks
            else np.empty(0, dtype=np.int64)
        )
        blk_ptr = np.zeros(counts.size + 1, dtype=np.int64)
        np.cumsum(counts, out=blk_ptr[1:])
        blk_ids = blk_ids.astype(np.int64)
    return CompiledSchedule(
        op=op,
        algorithm=algorithm,
        p=p,
        k=k,
        src=src.astype(np.int64),
        dst=dst.astype(np.int64),
        elems=elems.astype(np.int64),
        round_ptr=round_ptr,
        blk_ptr=blk_ptr,
        blk_ids=blk_ids,
    )


# ---------------------------------------------------------------------------
# Array-native generators for the O(p^2)-message alltoall families.
# Each mirrors its legacy generator's round structure and per-round message
# multiset exactly; no Msg objects are ever created.
# ---------------------------------------------------------------------------


def _direct_blocks(p: int, src: np.ndarray, dst: np.ndarray) -> tuple:
    """Per-round block CSR for direct alltoall messages: each message
    carries exactly its (src -> dst) pair block."""
    return np.ones(src.size, dtype=np.int64), src * p + dst


def kported_alltoall_ir(p: int, k: int, c: int) -> CompiledSchedule:
    """Direct alltoall (paper §2.1): ceil((p-1)/k) rounds of k shifted sends.

    Round t covers offsets d = 1+t*k .. min(1+(t+1)*k, p)-1; every processor
    i sends its per-pair block to (i + d) mod p for each offset in the round.
    """
    procs = np.arange(p, dtype=np.int64)
    rounds = []
    blocks = []
    offset = 1
    while offset < p:
        ds = np.arange(offset, min(offset + k, p), dtype=np.int64)
        src = np.tile(procs, ds.size)
        dst = (src + np.repeat(ds, p)) % p
        elems = np.full(src.size, c, dtype=np.int64)
        rounds.append((src, dst, elems))
        blocks.append(_direct_blocks(p, src, dst))
        offset += k
    return _from_rounds("alltoall", "kported", p, k, rounds, blocks)


def bruck_alltoall_ir(p: int, k: int, c: int) -> CompiledSchedule:
    """Radix-(k+1) message-combining alltoall, computed analytically.

    By translation symmetry every processor holds the same multiset of
    remaining offsets.  At the phase with ``radix_pow = (k+1)^t`` the live
    offsets are the multiples of ``radix_pow`` below ``p`` and the block
    count pooled at offset ``o`` is ``min(radix_pow, p - o)`` (the original
    offsets ``o..o+radix_pow-1`` that have collapsed onto it).  Processor q
    sends one message per nonzero digit value d of offset-digit t, carrying
    every pooled block whose digit is d, to ``(q + d*radix_pow) mod p``.

    Blocks are reconstructed analytically too: a block (a -> b) with
    original offset ``o0 = (b - a) mod p`` sits, at the phase clearing digit
    t, on processor ``q = (a + o0 mod radix_pow) mod p`` with collapsed
    offset ``o = o0 - o0 mod radix_pow``; the pooled blocks at (q, o) are
    ``{((q - low) mod p, (q + o) mod p) : low < pooled(o)}`` — common
    destination, ``pooled`` distinct sources.
    """
    r = k + 1
    procs = np.arange(p, dtype=np.int64)
    rounds = []
    blocks = []
    radix_pow = 1
    while radix_pow < p:
        offs = np.arange(0, p, radix_pow, dtype=np.int64)
        digit = (offs // radix_pow) % r
        pooled = np.minimum(radix_pow, p - offs)
        # message size per digit value (same at every processor)
        nblk = np.bincount(digit, weights=pooled.astype(np.float64), minlength=r)
        live = [d for d in range(1, r) if nblk[d] > 0]
        if live:
            # legacy emission order is q-major, digit-minor
            d_arr = np.asarray(live, dtype=np.int64)
            src = np.repeat(procs, d_arr.size)
            dst = (src + np.tile(d_arr * radix_pow, p)) % p
            elems = np.tile(
                (c * nblk[d_arr]).astype(np.int64), p
            )
            rounds.append((src, dst, elems))
            # --- per-message blocks (see docstring derivation) ------------
            m = digit > 0
            order = np.argsort(digit[m], kind="stable")  # digit-major, o asc
            o_ord = offs[m][order]
            pool_ord = pooled[m][order]
            hops = int(pool_ord.sum())
            rep_o = np.repeat(o_ord, pool_ord)
            starts = np.cumsum(pool_ord) - pool_ord
            rep_low = np.arange(hops, dtype=np.int64) - np.repeat(starts, pool_ord)
            # [p, hops]: row q = its blocks in (digit, o, low) template order
            blk_mat = ((procs[:, None] - rep_low[None, :]) % p) * p + (
                (procs[:, None] + rep_o[None, :]) % p
            )
            cnt_d = np.bincount(
                digit[m], weights=pooled[m].astype(np.float64), minlength=r
            ).astype(np.int64)
            counts = np.tile(cnt_d[d_arr], p)  # q-major, digit-minor
            flat = blk_mat.ravel()
            seg = np.repeat(np.arange(counts.size, dtype=np.int64), counts)
            flat = flat[np.lexsort((flat, seg))]  # canonical: ascending/msg
            blocks.append((counts, flat))
        else:
            rounds.append(
                (
                    np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.int64),
                    np.empty(0, dtype=np.int64),
                )
            )
            blocks.append(
                (np.empty(0, dtype=np.int64), np.empty(0, dtype=np.int64))
            )
        radix_pow *= r
    return _from_rounds("alltoall", "bruck", p, k, rounds, blocks)


def klane_alltoall_ir(topo: Topology, c: int) -> CompiledSchedule:
    """§2.3 alltoall: N-1 node rounds of n lane-legal steps, then a final
    on-node alltoall of n-1 steps; one c-element message per processor per
    step."""
    N, n, p = topo.num_nodes, topo.procs_per_node, topo.p
    idx = np.arange(p, dtype=np.int64)
    v, j = idx // n, idx % n
    elems = np.full(p, c, dtype=np.int64)
    rounds = []
    blocks = []
    for t in range(1, N):
        w = (v + t) % N
        for s in range(n):
            dst = w * n + (j + s) % n
            rounds.append((idx, dst, elems))
            blocks.append(_direct_blocks(p, idx, dst))
    for s in range(1, n):
        dst = v * n + (j + s) % n
        rounds.append((idx, dst, elems))
        blocks.append(_direct_blocks(p, idx, dst))
    return _from_rounds("alltoall", "klane", p, topo.k_lanes, rounds, blocks)


def fulllane_alltoall_ir(topo: Topology, c: int) -> CompiledSchedule:
    """§2.2 alltoall: n-1 on-node combining steps (N blocks per message)
    followed by N-1 node-ring steps of node-combined messages (n blocks)."""
    N, n, p = topo.num_nodes, topo.procs_per_node, topo.p
    idx = np.arange(p, dtype=np.int64)
    v, j = idx // n, idx % n
    rounds = []
    blocks = []
    elems_a = np.full(p, c * N, dtype=np.int64)
    cnt_a = np.full(p, N, dtype=np.int64)
    for s in range(1, n):
        dst = v * n + (j + s) % n
        rounds.append((idx, dst, elems_a))
        # (v, j) -> (v, l): blocks src*p + rank(w, l) for all nodes w
        flat = (
            idx[:, None] * p
            + np.arange(N, dtype=np.int64)[None, :] * n
            + (dst % n)[:, None]
        ).ravel()
        blocks.append((cnt_a, flat))
    elems_b = np.full(p, c * n, dtype=np.int64)
    cnt_b = np.full(p, n, dtype=np.int64)
    for t in range(1, N):
        dst = ((v + t) % N) * n + j
        rounds.append((idx, dst, elems_b))
        # (v, l) -> (w, l): node-combined blocks rank(v, j')*p + dst, all j'
        flat = (
            (v[:, None] * n + np.arange(n, dtype=np.int64)[None, :]) * p
            + dst[:, None]
        ).ravel()
        blocks.append((cnt_b, flat))
    return _from_rounds("alltoall", "fulllane", p, topo.k_lanes, rounds, blocks)


#: (op, algorithm) -> array-native generator with the ALGORITHMS signature.
IR_GENERATORS: dict[tuple[str, str], Callable] = {
    ("alltoall", "kported"): lambda topo, k, c: kported_alltoall_ir(topo.p, k, c),
    ("alltoall", "bruck"): lambda topo, k, c: bruck_alltoall_ir(topo.p, k, c),
    ("alltoall", "klane"): lambda topo, k, c: klane_alltoall_ir(topo, c),
    ("alltoall", "fulllane"): lambda topo, k, c: fulllane_alltoall_ir(topo, c),
}


# ---------------------------------------------------------------------------
# Process-wide schedule cache (thread-safe; optimized entries fingerprinted).
#
# ISSUE 5: optimized schedules are keyed on ``(op, algorithm, topo, k, c,
# root, opt_mode, pipeline_fingerprint)`` — the fingerprint
# (:func:`repro.core.passes.pipeline_fingerprint`) hashes the pass names +
# a version salt, so changing a pipeline's composition or semantics
# invalidates exactly the entries it produced.  On top of the per-``c``
# entries sits a **recipe cache**: a pipeline whose passes are all
# ``recipe_safe`` (payload-independent message permutations / re-roundings
# — reorder, color without a machine, compaction) produces the *same*
# rewrite at every payload size, so the pipeline runs once on a
# tagged-payload copy (``elems = arange(M)`` — the output's elems array IS
# the permutation) and every other payload replays the recorded
# ``(morder, round_ptr)`` with one gather.  That is what stops the
# selector's ``opt:`` candidates from re-running the whole pass pipeline
# on every ``crossover_table`` probe: probes differ only in ``c``.  The
# first recipe application is still oracle-validated; replays at other
# payloads are not re-checked — block structure and round assignment are
# identical and the oracle never reads ``elems``.
# ---------------------------------------------------------------------------

_LOCK = threading.RLock()
_CACHE: dict[tuple, CompiledSchedule] = {}
_RECIPES: dict[tuple, dict] = {}
_CACHE_HITS = 0
_CACHE_MISSES = 0
_RECIPE_HITS = 0
_RECIPE_MISSES = 0
# Keys seeded from the on-disk artifact store (repro.store warm-start).
# Membership survives FIFO eviction on purpose: a rebuild of *any* key the
# store had already materialized is a recompile the serving layer promised
# not to pay — counted in _STORE_RECOMPILES and the
# ``schedule_cache.store_recompiles`` metric (the load benchmark's
# "zero recompiles of store-resident artifacts" acceptance gate).
_STORE_RESIDENT: set[tuple] = set()
_STORE_RECOMPILES = 0
_CACHE_MAX = 512
# Paper-scale alltoall entries cost tens of MB each (message arrays plus the
# lazily-built [R, p] stats grids), so bound resident bytes as well as count;
# insertion evicts oldest-first (FIFO) until both bounds hold.  The bound is
# only enforced at insertion: stats grids built *after* an entry is cached
# grow resident bytes past the cap until the next insertion re-measures
# (acceptable overshoot — one klane p=1152 stats set is ~120 MB).
_CACHE_MAX_BYTES = 512 * 1024 * 1024


def _entry_bytes(cs: CompiledSchedule) -> int:
    n = cs.src.nbytes + cs.dst.nbytes + cs.elems.nbytes + cs.round_ptr.nbytes
    if cs.has_blocks:
        n += cs.blk_ptr.nbytes + cs.blk_ids.nbytes
    for st in cs._stats.values():
        for f in dataclasses.fields(st):
            v = getattr(st, f.name)
            if isinstance(v, np.ndarray):
                n += v.nbytes
    return n


def compiled_schedule(
    op,
    algorithm: str | None = None,
    topo: Topology | None = None,
    k: int | None = None,
    c: int | None = None,
    root: int = 0,
    *,
    optimize: str | None = None,
    faults=None,
) -> CompiledSchedule:
    """Cached compiled schedule for an ``ALGORITHMS`` family.

    **PlanRequest overload** (ISSUE 8 API redesign): the first argument may
    be a :class:`repro.api.PlanRequest` instead of the op string, in which
    case only ``algorithm`` is required — the topology, generation ``k``
    and payload ``c`` are derived from the request exactly the way the
    selector's fallback rung derives them (``k = min(k_lanes,
    procs_per_node)``; ``c`` is the total payload for broadcast, the
    per-proc/per-pair block otherwise), an ``"opt:"``-prefixed algorithm
    selects the ``"color"`` pipeline, and the request's faults ride along::

        compiled_schedule(PlanRequest("alltoall", 869, num_nodes=3,
                                      procs_per_node=4, k_lanes=2),
                          plan.algorithm)

    The positional 9-argument form below stays the compiler-internal
    entry point.

    Alltoall families come from the array-native generators; the tree
    families (O(p log p) messages) generate the legacy schedule and compile
    it.  Cached process-wide keyed by ``(op, algorithm, topo, k, c, root,
    optimize)`` — cached entries share their lazily-built per-topology round
    statistics, so re-simulating a cached schedule under the same machine
    shape is pure array arithmetic.

    ``faults`` (a :class:`repro.core.faults.FaultSpec`) requests the
    *repaired* schedule for a degraded machine: the healthy (optionally
    optimized) entry is built first, then rewritten by
    :func:`repro.core.passes.repair_schedule` and oracle-revalidated.  The
    fault fingerprint is folded into the cache key, so healthy-topology
    entries — including recipe replays — are never served under faults
    (the ISSUE 6 cache-invalidation rule: a tuned schedule cached for a
    healthy topology is silently wrong the moment the topology degrades).

    ``optimize`` selects an optimizer pipeline from
    :data:`repro.core.passes.OPT_MODES` (``"lane"`` keeps strict
    lane-legality, ``"ported"`` compacts adjacent rounds up to port width k,
    ``"reorder"`` list-schedules messages into the earliest dependency- and
    budget-legal round regardless of adjacency, ``"split"`` splits payloads
    across the k lanes, ``"color"`` runs the conflict-graph coloring packer
    at the auto-chosen budget); the optimized schedule is validated by the
    array-native oracle before it enters the cache.  Optimized entries are
    keyed on the pass pipeline's fingerprint as well, and pipelines whose
    passes are all payload-independent (``recipe_safe``) run once per
    structure and replay as a recorded permutation recipe at every other
    payload size — see the cache notes above.  Split factors clamp to
    ``elems``, so optimized entries are piecewise-affine in ``c`` — the
    selector's piecewise fits (``selector.piecewise_cost``) handle any
    regime flip the rewrites cause.
    """
    global _CACHE_HITS, _CACHE_MISSES, _RECIPE_HITS, _RECIPE_MISSES
    global _STORE_RECOMPILES
    if not isinstance(op, str):
        req = op  # duck-typed PlanRequest (api imports this module, not v.v.)
        if algorithm is None:
            raise TypeError(
                "compiled_schedule(PlanRequest, ...) requires an algorithm "
                "(e.g. plan(request).algorithm)"
            )
        alg, opt_mode = algorithm, optimize
        if alg.startswith("opt:"):
            alg, opt_mode = alg[4:], "color"
        req_faults = req.faults
        if req_faults is not None and req_faults.is_healthy:
            req_faults = None
        return compiled_schedule(
            req.op,
            alg,
            Topology(req.num_nodes, req.procs_per_node, req.k_lanes),
            min(req.k_lanes, req.procs_per_node),
            req.payload_elems if req.op == "broadcast"
            else max(1, req.payload_elems),
            root,
            optimize=opt_mode,
            faults=req_faults,
        )
    fingerprint = None
    passes = None
    if optimize is not None:
        from repro.core.passes import OPT_MODES, pipeline_fingerprint

        try:
            factory = OPT_MODES[optimize]
        except KeyError:
            raise ValueError(
                f"unknown optimize mode {optimize!r}; expected one of "
                f"{sorted(OPT_MODES)}"
            ) from None
        passes = factory(topo)
        fingerprint = pipeline_fingerprint(passes)
    fault_fp = None
    if faults is not None and not faults.is_healthy:
        fault_fp = faults.fingerprint()
    key = (
        op,
        algorithm,
        topo.num_nodes,
        topo.procs_per_node,
        topo.k_lanes,
        k,
        c,
        root,
        optimize,
        fingerprint,
        fault_fp,
    )
    with _LOCK:
        hit = _CACHE.get(key)
        if hit is not None:
            _CACHE_HITS += 1
        else:
            _CACHE_MISSES += 1
            if key in _STORE_RESIDENT:
                _STORE_RECOMPILES += 1
                obs_metrics.counter("schedule_cache.store_recompiles").inc()
    if hit is not None:
        obs_metrics.counter("schedule_cache.hits").inc()
        if TRACER:
            TRACER.event("cache.hit", op=op, algorithm=algorithm,
                         optimize=optimize, c=c, fault_fp=fault_fp)
        return hit
    obs_metrics.counter("schedule_cache.misses").inc()
    if root != 0:
        raise ValueError("the ALGORITHMS registry generates root=0 schedules")
    sp = TRACER.start(
        "compile", op=op, algorithm=algorithm, nodes=topo.num_nodes,
        ppn=topo.procs_per_node, lanes=topo.k_lanes, k=k, c=c,
        optimize=optimize, fingerprint=fingerprint, fault_fp=fault_fp,
    ) if TRACER else None
    try:
        cs, path = _build_entry(op, algorithm, topo, k, c, root,
                                optimize=optimize, faults=faults,
                                fault_fp=fault_fp, passes=passes, key=key)
    except BaseException:
        if sp:
            TRACER.finish(sp, path="error")
        raise
    if sp:
        TRACER.finish(sp, path=path, rounds=cs.num_rounds, msgs=cs.num_msgs)
    new_bytes = _entry_bytes(cs)
    with _LOCK:
        while _CACHE and (
            len(_CACHE) >= _CACHE_MAX
            or _cache_bytes() + new_bytes > _CACHE_MAX_BYTES
        ):
            _CACHE.pop(next(iter(_CACHE)))
        _CACHE[key] = cs
    return cs


def _build_entry(op, algorithm, topo, k, c, root, *, optimize, faults,
                 fault_fp, passes, key) -> tuple[CompiledSchedule, str]:
    """The cache-miss build path of :func:`compiled_schedule`, factored out
    so the compile trace span has a single open/close boundary."""
    if fault_fp is not None:
        # repair is a rewrite of the healthy entry (which stays cached and
        # reusable for other fault sets), never a regeneration
        base = compiled_schedule(op, algorithm, topo, k, c, root,
                                 optimize=optimize)
        from repro.core.passes import repair_schedule

        cs, _ = repair_schedule(base, faults, topo=topo)
        return cs, "repair"
    if optimize is not None:
        base = compiled_schedule(op, algorithm, topo, k, c, root)
        if all(getattr(ps, "recipe_safe", False) for ps in passes):
            return _optimize_via_recipe(base, key[:6] + key[7:], passes), "recipe"
        from repro.core.passes import optimize_schedule

        cs, _ = optimize_schedule(base, optimize, topo=topo, validate=True)
        return cs, "optimize"
    gen = IR_GENERATORS.get((op, algorithm))
    if gen is not None:
        return gen(topo, k, c), "generate"
    legacy = sched.ALGORITHMS[(op, algorithm)](topo, k, c)
    return compile_schedule(legacy, with_blocks=True), "compile_legacy"


def _optimize_via_recipe(
    base: CompiledSchedule, recipe_key: tuple, passes: list
) -> CompiledSchedule:
    """Optimize ``base`` through a payload-independent pipeline, running the
    passes at most once per structure: the pipeline is replayed on a
    tagged-payload copy whose ``elems`` are the message indices, so the
    output's ``elems`` array *is* the message permutation; every subsequent
    payload size applies the recorded ``(morder, round_ptr)`` with one
    gather.  The first materialized application is machine-checked by the
    validity oracle (raising on corruption, exactly like the non-recipe
    path); replays at other payloads reuse that verdict — the oracle never
    reads ``elems`` and the block structure is identical by construction."""
    global _RECIPE_HITS, _RECIPE_MISSES
    from repro.core.passes import PassManager
    from repro.core.validate import validate_schedule

    # counter updates stay inside _LOCK: plain += on module globals is a
    # read-modify-write and concurrent recipe replays would lose increments
    # (the cache counters above already do this; these were racy until ISSUE 7)
    with _LOCK:
        rec = _RECIPES.get(recipe_key)
        if rec is None:
            _RECIPE_MISSES += 1
        else:
            _RECIPE_HITS += 1
    if rec is None:
        obs_metrics.counter("schedule_recipes.misses").inc()
        if TRACER:
            TRACER.event("recipe.miss", op=recipe_key[0], algorithm=recipe_key[1])
        tagged = dataclasses.replace(
            base,
            elems=np.arange(base.num_msgs, dtype=np.int64),
            _stats={},
        )
        out, _ = PassManager(passes).run(tagged)
        rec = (
            {"identity": True, "validated": True}
            if out is tagged
            else {
                "identity": False,
                "validated": False,
                "morder": out.elems.copy(),
                "round_ptr": out.round_ptr.copy(),
            }
        )
        with _LOCK:
            rec = _RECIPES.setdefault(recipe_key, rec)
    else:
        obs_metrics.counter("schedule_recipes.hits").inc()
        if TRACER:
            TRACER.event("recipe.replay", op=recipe_key[0],
                         algorithm=recipe_key[1])
    if rec["identity"]:
        return base
    morder = rec["morder"]
    blk_ptr, blk_ids = gather_block_csr(base.blk_ptr, base.blk_ids, morder)
    cs = dataclasses.replace(
        base,
        src=base.src[morder],
        dst=base.dst[morder],
        elems=base.elems[morder],
        round_ptr=rec["round_ptr"],
        blk_ptr=blk_ptr,
        blk_ids=blk_ids,
        _stats={},
    )
    if not rec["validated"]:
        osp = TRACER.start("oracle", mode="full", where="recipe") if TRACER \
            else None
        try:
            report = validate_schedule(cs)
        except BaseException:
            if osp:
                TRACER.finish(osp, outcome="error")
            raise
        if osp:
            TRACER.finish(osp, ok=report.ok)
        report.raise_if_invalid()
        rec["validated"] = True
    return cs


def _cache_bytes() -> int:
    return sum(_entry_bytes(cs) for cs in _CACHE.values())


def cache_export() -> tuple[dict[tuple, CompiledSchedule], dict[tuple, dict]]:
    """One coherent snapshot of the process cache: ``(entries, recipes)``
    as plain dicts keyed by the full cache/recipe key tuples.  This is the
    persistence boundary for :class:`repro.store.ArtifactStore` — the
    values are the cached frozen ``CompiledSchedule`` objects themselves
    (safe to share: entries are never mutated after insertion) and
    shallow copies of the recipe dicts."""
    with _LOCK:
        return dict(_CACHE), {rk: dict(rec) for rk, rec in _RECIPES.items()}


def cache_seed(
    entries: dict[tuple, CompiledSchedule],
    recipes: dict[tuple, dict] | None = None,
    *,
    resident: bool = True,
) -> int:
    """Warm-start the process cache with prebuilt entries (the
    :class:`repro.store.ArtifactStore` load path).  Existing keys are kept
    (a live entry is never clobbered by a disk copy), insertion respects
    the count/byte bounds with the same FIFO eviction as a compile miss,
    and seeding moves no hit/miss counters — a warm start is neither.
    With ``resident=True`` the seeded keys are tracked so any later
    rebuild of one of them counts as a store recompile
    (``schedule_cache_info()["store_recompiles"]``).  Returns the number
    of schedule entries actually inserted."""
    inserted = 0
    with _LOCK:
        for key, cs in entries.items():
            if resident:
                _STORE_RESIDENT.add(key)
            if key in _CACHE:
                continue
            new_bytes = _entry_bytes(cs)
            while _CACHE and (
                len(_CACHE) >= _CACHE_MAX
                or _cache_bytes() + new_bytes > _CACHE_MAX_BYTES
            ):
                _CACHE.pop(next(iter(_CACHE)))
            _CACHE[key] = cs
            inserted += 1
        for rk, rec in (recipes or {}).items():
            _RECIPES.setdefault(rk, rec)
    return inserted


def schedule_cache_info() -> dict:
    # the store's race counter rides along so one info() call answers
    # "is the shared store healthy" too (lazy import: the store imports
    # this module lazily in the other direction)
    from repro.store.artifacts import read_race_count

    races = read_race_count()
    with _LOCK:
        return {
            "hits": _CACHE_HITS,
            "misses": _CACHE_MISSES,
            "recipe_hits": _RECIPE_HITS,
            "recipe_misses": _RECIPE_MISSES,
            "size": len(_CACHE),
            "recipes": len(_RECIPES),
            "bytes": _cache_bytes(),
            "store_resident": len(_STORE_RESIDENT),
            "store_recompiles": _STORE_RECOMPILES,
            "store_read_races": races,
        }


def schedule_cache_clear() -> None:
    """Drop every cached entry and recipe, and zero the counters."""
    global _CACHE_HITS, _CACHE_MISSES, _RECIPE_HITS, _RECIPE_MISSES
    global _STORE_RECOMPILES
    with _LOCK:
        _CACHE.clear()
        _RECIPES.clear()
        _STORE_RESIDENT.clear()
        _CACHE_HITS = 0
        _CACHE_MISSES = 0
        _RECIPE_HITS = 0
        _RECIPE_MISSES = 0
        _STORE_RECOMPILES = 0


def schedule_cache_reset() -> None:
    """Zero the hit/miss counters while *keeping* cached entries and
    recipes — the ``schedule_cache_info`` counterpart for measuring the
    hit rate of one workload window without cold-starting the cache
    (``schedule_cache_clear`` drops the entries too).  Store-resident
    key tracking survives; only the recompile counter rewinds."""
    global _CACHE_HITS, _CACHE_MISSES, _RECIPE_HITS, _RECIPE_MISSES
    global _STORE_RECOMPILES
    with _LOCK:
        _CACHE_HITS = 0
        _CACHE_MISSES = 0
        _RECIPE_HITS = 0
        _RECIPE_MISSES = 0
        _STORE_RECOMPILES = 0
