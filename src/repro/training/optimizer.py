"""AdamW with ZeRO-1-style sharded moments.

Pure-functional: state is a pytree mirroring params.  Moment dtype is
configurable (bf16 moments for the >=200B configs keep the optimizer under
the v5e HBM budget; see DESIGN.md §5).  Sharding of the moments is applied
by the caller via ``partition_specs(..., fsdp=True)`` — the moments always
use the FSDP rules even when the params do not (that *is* ZeRO-1: optimizer
state sharded over the data axis, with XLA inserting the gather around the
update)."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["OptConfig", "init_opt_state", "adamw_update", "global_norm"]


@dataclasses.dataclass(frozen=True)
class OptConfig:
    learning_rate: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    moment_dtype: str = "float32"


def init_opt_state(params, cfg: OptConfig) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def _schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.learning_rate * warm


def adamw_update(grads, state, params, cfg: OptConfig):
    """Returns (new_params, new_state, info)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = _schedule(cfg, state["step"])
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + g * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g * g * (1 - b2)
        mhat = m32 / bc1
        vhat = v32 / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    info = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, info
