"""MoE expert-parallel dispatch through the paper's hierarchical all-to-all.

Runs on 8 CPU devices (mesh 2 pods x 4 lanes):

1. routes a batch of tokens to experts with the *flat* XLA all-to-all and
   with ``fulllane_all_to_all`` (paper §2.2: on-node combine, then
   node-level exchange) inside shard_map — results must be identical;
2. compares the collective bytes in the two compiled HLO modules.

  PYTHONPATH=src python examples/moe_ep_demo.py
"""

import jax

jax.config.update("jax_num_cpu_devices", 8)

import jax.numpy as jnp
import numpy as np
from jax import shard_map
from jax.sharding import PartitionSpec as P

from repro.core import collectives as C
from repro.launch.hloanalysis import analyze_module


def main():
    mesh = jax.make_mesh((2, 4), ("pod", "lane"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    P_TOTAL = 8  # devices == "expert groups"
    TOK, D = 16, 32  # tokens per device destined per expert-group, width

    rng = np.random.RandomState(0)
    # x[d] on device s: tokens from s for expert-group d
    x = rng.randn(8, P_TOTAL, TOK, D).astype(np.float32)

    def dispatch(a2a):
        def f(xs):
            local = xs[0]  # [P_TOTAL, TOK, D]
            routed = a2a(local.reshape(P_TOTAL, TOK * D))
            return routed.reshape(P_TOTAL, TOK, D)[None]
        return jax.jit(shard_map(f, mesh=mesh, in_specs=P(("pod", "lane")),
                                 out_specs=P(("pod", "lane"))))

    flat = dispatch(lambda v: C.flat_all_to_all(v, "pod", "lane"))
    hier = dispatch(lambda v: C.fulllane_all_to_all(v, "pod", "lane"))

    out_f = np.asarray(flat(x))
    out_h = np.asarray(hier(x))
    np.testing.assert_allclose(out_f, out_h, rtol=1e-6)
    print("dispatch equivalence: OK (flat == hierarchical)")

    for name, fn in [("flat", flat), ("fulllane", hier)]:
        comp = fn.lower(jax.ShapeDtypeStruct(x.shape, jnp.float32)).compile()
        cost = analyze_module(comp.as_text())
        print(f"{name:9s} collective bytes/device: "
              f"{ {k: v for k, v in sorted(cost.collective_bytes.items())} }")
    print("""
On this toy mesh both phases are ICI; on the production 2-pod mesh the
hierarchical form combines each pod's cross-pod traffic into one large
message per destination pod with every chip driving a lane concurrently —
the paper's full-lane argument.  See EXPERIMENTS.md §Perf (deepseek EP).
""")


if __name__ == "__main__":
    main()
