"""ISSUE 4: the conflict-graph coloring round packer (``ColorRounds``),
cost-aware k-lane payload splitting (``SplitPayloads(machine=...)``), the
zero-block split-part causality lift in ``validate.block_dependencies``,
the shared simulator costing hooks, and the ``merge(split(...))``
round-trip property on all four alltoall families and both machine
models."""

import dataclasses

import numpy as np
import pytest

from repro.core import schedule as S
from repro.core import schedule_ir as IR
from repro.core import selector
from repro.core.passes import (
    ColorRounds,
    PassManager,
    ReorderRounds,
    SplitPayloads,
    optimize_schedule,
)
from repro.core.simulate import lane_time, port_time, simulate
from repro.core.topology import (
    Machine,
    Topology,
    hydra_machine,
    nvlink_ib_machine,
)
from repro.core.validate import block_dependencies, validate_schedule

HYDRA = hydra_machine()


def _machine(topo: Topology) -> Machine:
    return Machine(topo=topo, cost=HYDRA.cost)


# ---------------------------------------------------------------------------
# ColorRounds: packing behaviour
# ---------------------------------------------------------------------------


def test_color_requires_blocks_and_divisible_nodes():
    blockless = IR.compile_schedule(S.kported_scatter(8, 2, 3))
    with pytest.raises(ValueError, match="block"):
        ColorRounds(limit=1, procs_per_node=4).apply(blockless)
    cs = IR.kported_alltoall_ir(8, 2, 3)
    with pytest.raises(ValueError, match="divisible"):
        ColorRounds(limit=1, procs_per_node=3).apply(cs)


def test_color_identity_when_input_already_packed():
    """A schedule the coloring reproduces exactly comes back as the same
    object (so PassManager records it as not-applied)."""
    cs = IR.kported_alltoall_ir(8, 2, 3)  # ceil(7/2)=4 saturated rounds
    assert ColorRounds(limit=2, procs_per_node=4).apply(cs) is cs


def test_color_respects_dependency_chains():
    """Bruck's phases are fully chained; with the refined class-purity rule
    (an intra message already network-priced in its input round may share a
    color with inter traffic) the coloring reproduces exactly the nonempty
    phase count — no more, no less."""
    cs = IR.bruck_alltoall_ir(27, 2, 5)
    nonempty = int((np.diff(cs.round_ptr) > 0).sum())
    col = ColorRounds(limit=None, procs_per_node=9, mult=4).apply(cs)
    assert col.num_rounds == nonempty
    assert validate_schedule(col).ok


def test_color_budget_ladder_on_klane_alltoall():
    """The klane alltoall packs to ceil(inter/L) + ceil(intra/L) at budget
    L — message granularity reproduces the optimal regular packing at
    every rung of the ladder."""
    topo = Topology(4, 6, 2)
    cs = IR.compiled_schedule("alltoall", "klane", topo, 2, 7)
    N, n = 4, 6
    for mult in (1, 2, 4):
        L = mult * cs.k
        col = ColorRounds(limit=None, procs_per_node=n, mult=mult).apply(cs)
        assert col.num_rounds == -(-(N - 1) * n // L) + -(-(n - 1) // L)
        assert validate_schedule(col).ok
        assert col.total_elems() == cs.total_elems()


def test_color_splits_rounds_first_fit_cannot():
    """Message granularity: the broadcast tree's sender-side waves pack
    below what whole-round first-fit reaches (the k-lane broadcast at the
    paper topology: first-fit stops at 23 rounds, coloring reaches <= 12)."""
    topo = Topology(36, 32, 2)
    base = IR.compiled_schedule("broadcast", "klane", topo, 2, 10_000)
    ff = ReorderRounds(limit=None, procs_per_node=32).apply(base)
    ff = ReorderRounds(limit=2 * base.k, procs_per_node=32).apply(ff)
    col = ColorRounds(limit=None, procs_per_node=32, mult=4).apply(base)
    assert col.num_rounds < ff.num_rounds < base.num_rounds
    assert validate_schedule(col).ok
    assert (
        simulate(col, HYDRA, ported=True).time_us
        < simulate(ff, HYDRA, ported=True).time_us
    )


@pytest.mark.parametrize("op_alg", sorted(S.ALGORITHMS))
def test_color_valid_and_lex_raced_never_worse(op_alg):
    """ColorRounds is not provably never-slower, so the contract is: every
    coloring is oracle-valid and volume-preserving, and under the lex
    policy (raced against the first-fit baseline) the pipeline result is
    never slower than the input on either port model."""
    op, alg = op_alg
    topo = Topology(3, 4, 2)
    machine = _machine(topo)
    cs = IR.compiled_schedule(op, alg, topo, 2, 13)
    for mult in (1, 4):
        col = ColorRounds(limit=None, procs_per_node=4, mult=mult).apply(cs)
        assert validate_schedule(col).ok
        assert col.total_elems() == cs.total_elems()
    for ported in (False, True):
        pm = PassManager(
            [
                ReorderRounds(limit=None, procs_per_node=4),
                ColorRounds(limit=None, procs_per_node=4, mult=4),
            ],
            machine=machine,
            ported=ported,
            policy="lex",
            validate=True,
        )
        opt, _ = pm.run(cs)
        assert validate_schedule(opt).ok
        assert (
            simulate(opt, machine, ported=ported).time_us
            <= simulate(cs, machine, ported=ported).time_us + 1e-9
        )


def test_color_headline_klane_alltoall_paper_scale():
    """ISSUE 4 acceptance: at the paper's 36x32/k=2 the coloring packer
    must pack the k-lane alltoall below PR 3's 288 first-fit rounds
    (target <= 260) with >= 4.2x simulated at c=1, oracle-valid."""
    topo = Topology(36, 32, 2)
    base = IR.klane_alltoall_ir(topo, 1)
    ff = ReorderRounds(limit=None, procs_per_node=32).apply(base)
    ff = ReorderRounds(limit=2 * base.k, procs_per_node=32).apply(ff)
    assert ff.num_rounds == 288  # PR 3's first-fit plateau
    col = ColorRounds(limit=None, procs_per_node=32, mult=4).apply(base)
    assert col.num_rounds < ff.num_rounds
    assert col.num_rounds <= 260
    base_us = simulate(base, HYDRA).time_us
    col_us = simulate(col, HYDRA).time_us
    assert base_us / col_us >= 4.2
    assert col_us < simulate(ff, HYDRA).time_us
    assert validate_schedule(col).ok
    assert col.total_elems() == base.total_elems()


def test_optimize_mode_color_via_cache_and_selector_parse():
    topo = Topology(4, 6, 2)
    base = IR.compiled_schedule("alltoall", "klane", topo, 2, 7)
    opt = IR.compiled_schedule("alltoall", "klane", topo, 2, 7, optimize="color")
    assert opt.num_rounds < base.num_rounds
    assert (
        IR.compiled_schedule("alltoall", "klane", topo, 2, 7, optimize="color")
        is opt
    )
    assert selector._parse_alg("opt:klane") == ("klane", "color")
    with pytest.raises(ValueError, match="topology"):
        optimize_schedule(base, "color")  # mode needs topo= or machine=


# ---------------------------------------------------------------------------
# zero-block split parts: the dependency lift (ISSUE 4 bugfix satellite)
# ---------------------------------------------------------------------------


def _forward_chain_split():
    """p=6 alltoall fragment: 0 -> 1 delivers block (0->2), 1 -> 2 forwards
    it (split into 4 parts, 3 of them zero-block), 2 -> 3 forwards on."""
    p = 6
    sch = S.Schedule(
        op="alltoall",
        algorithm="toy",
        p=p,
        k=1,
        rounds=(
            S.Round((S.Msg(0, 1, 8, (2,)),)),
            S.Round((S.Msg(1, 2, 8, (2,)),)),
            S.Round((S.Msg(2, 3, 8, (2,)),)),
        ),
    )
    cs = IR.compile_schedule(sch, with_blocks=True)
    sp = IR.split_messages(cs, np.array([1, 4, 1], dtype=np.int64))
    assert np.diff(sp.blk_ptr).tolist() == [1, 1, 0, 0, 0, 1]
    return sp


def test_zero_block_parts_have_no_edges_without_lift():
    """Pins the hazard the lift fixes: without it the zero-block parts are
    dependency-free (a packer may hoist them ahead of their payload's
    producer) and the downstream forwarder waits for only one part."""
    sp = _forward_chain_split()
    dep_ptr, dep_ids = block_dependencies(sp, lift_zero_block=False)
    ndep = np.diff(dep_ptr)
    assert ndep[2] == ndep[3] == ndep[4] == 0  # the zero-block parts
    assert ndep[5] == 1  # forwarder waits for the one block-bearing part


def test_zero_block_lift_pins_split_part_semantics():
    """The lift: parts inherit their siblings' providers, and a consumer
    waits for ALL parts of the delivering payload."""
    sp = _forward_chain_split()
    dep_ptr, dep_ids = block_dependencies(sp)

    def deps(i):
        return dep_ids[dep_ptr[i]:dep_ptr[i + 1]].tolist()

    assert deps(1) == [0]
    assert deps(2) == deps(3) == deps(4) == [0]  # requirement-side lift
    assert deps(5) == [1, 2, 3, 4]  # acquisition-side lift: all parts


def test_color_does_not_hoist_zero_block_parts():
    """ISSUE 4 acceptance for the satellite: the message-granularity packer
    keeps every split part strictly after the payload's producer and the
    downstream forwarder strictly after every part."""
    sp = _forward_chain_split()
    col = ColorRounds(limit=8, procs_per_node=6).apply(sp)
    # the toy is a partial alltoall: compare data-flow health against the
    # input instead of the full-op postcondition
    rep, base_rep = validate_schedule(col), validate_schedule(sp)
    assert rep.causality_violations == 0
    assert rep.missing_final == base_rep.missing_final
    rid = col.round_ids()
    provider_round = int(rid[col.src == 0][0])
    part_rounds = rid[col.src == 1]
    consumer_round = int(rid[col.src == 2][0])
    assert (part_rounds > provider_round).all()
    assert (consumer_round > part_rounds).all()


# ---------------------------------------------------------------------------
# cost-aware SplitPayloads + the shared costing hooks
# ---------------------------------------------------------------------------


def test_costing_hooks_match_simulator_reference():
    """port_time/lane_time are THE simulator formulas: spot-check them
    against the reference expressions for both port models."""
    cost = HYDRA.cost
    t = port_time(cost, 100.0, 1, True, 2, ported=False)
    assert t == pytest.approx(cost.alpha_inter + cost.beta_inter * 100.0)
    t = port_time(cost, 100.0, 4, True, 2, ported=True)
    ref = max(
        cost.alpha_inter + cost.beta_inter * 100.0 / 2, cost.alpha_inter * 2
    )
    assert t == pytest.approx(ref)
    t = port_time(cost, 100.0, 4, False, 2, ported=True, alpha_batches=False)
    assert t == pytest.approx(cost.alpha_intra + cost.beta_intra * 100.0 / 2)
    t = lane_time(cost, 1000.0, 3, 2)
    assert t == pytest.approx(cost.alpha_inter + cost.beta_inter * 1000.0 / 2)


def test_cost_split_skips_zero_gain_splits():
    """klane alltoall in the 1-ported model: every node already drives more
    streams than lanes and the port term ignores the message count, so the
    model prices every split at zero — the cost-aware pass must be an
    identity where the uniform pass doubles the message count."""
    topo = Topology(4, 6, 2)
    cs = IR.compiled_schedule("alltoall", "klane", topo, 2, 7)
    uniform = SplitPayloads(parts=2).apply(cs)
    assert uniform.num_msgs == 2 * cs.num_msgs  # the junk the lex policy
    # previously had to reject wholesale
    assert SplitPayloads(machine=_machine(topo), ported=False).apply(cs) is cs


def test_cost_split_matches_uniform_where_the_model_pays():
    """k-ported model, lone senders: the alpha/beta trade-off predicts the
    same lane-filling factors the uniform pass uses — same simulated time,
    and never more messages."""
    topo = Topology(4, 6, 2)
    machine = _machine(topo)
    cs = IR.compiled_schedule("broadcast", "klane", topo, 2, 10_000)
    uniform = SplitPayloads(parts=topo.k_lanes).apply(cs)
    costed = SplitPayloads(machine=machine, ported=True).apply(cs)
    assert costed.num_msgs <= uniform.num_msgs
    assert simulate(costed, machine, ported=True).time_us == pytest.approx(
        simulate(uniform, machine, ported=True).time_us, rel=1e-12
    )
    assert (
        simulate(costed, machine, ported=True).time_us
        < simulate(cs, machine, ported=True).time_us - 1e-9
    )
    assert validate_schedule(costed).ok


def test_cost_split_identity_in_one_ported_model_is_not_a_forgone_gain():
    """In the 1-ported model no split can pay: the sender's port serializes
    its bytes regardless of message count, and in a lane-starved round the
    worst port term already dominates the node lane term.  The cost-aware
    pass is an identity there — and the uniform split on the same schedule
    indeed buys nothing (same simulated time, more messages), confirming
    the identity forgoes no gain even on a 1-stream-per-node broadcast."""
    topo = Topology(4, 4, 4)
    machine = _machine(topo)
    cs = IR.compiled_schedule("broadcast", "kported", topo, 1, 100_000)
    assert SplitPayloads(machine=machine, ported=False).apply(cs) is cs
    uniform = SplitPayloads(parts=topo.k_lanes).apply(cs)
    assert uniform.num_msgs > cs.num_msgs
    assert simulate(uniform, machine).time_us == pytest.approx(
        simulate(cs, machine).time_us, rel=1e-12
    )


# ---------------------------------------------------------------------------
# merge(split(...)) round-trip property (ISSUE 4 test-coverage satellite)
# ---------------------------------------------------------------------------


def _canon(cs):
    """Messages sorted by (round, src, dst) — merge_messages' output order."""
    rid = cs.round_ids()
    key = (rid * cs.p + cs.src) * cs.p + cs.dst
    order = np.argsort(key, kind="stable")
    blk_ptr, blk_ids = IR.gather_block_csr(cs.blk_ptr, cs.blk_ids, order)
    return dataclasses.replace(
        cs,
        src=cs.src[order],
        dst=cs.dst[order],
        elems=cs.elems[order],
        blk_ptr=blk_ptr,
        blk_ids=blk_ids,
        _stats={},
    )


_A2A_FAMILIES = ["kported", "bruck", "klane", "fulllane"]


@pytest.mark.parametrize("alg", _A2A_FAMILIES)
@pytest.mark.parametrize("seed", [0, 1])
def test_merge_split_roundtrip_bit_exact(alg, seed):
    """merge_messages(split_messages(cs, f)) is bit-exact (up to the
    canonical in-round message order) for random factor vectors including
    f > elems and f > nblk, on all four alltoall families; the simulated
    cost is unchanged on both machine models and both port models."""
    topo = Topology(3, 4, 2)
    cs = IR.compiled_schedule("alltoall", alg, topo, 2, 3)
    assert IR.merge_messages(cs) is cs  # no same-(round,src,dst) duplicates
    rng = np.random.default_rng(seed * 7919 + len(alg))
    hi = int(max(cs.elems.max(), np.diff(cs.blk_ptr).max())) * 2 + 2
    factors = rng.integers(1, hi, size=cs.num_msgs)
    sp = IR.split_messages(cs, factors)
    assert sp.total_elems() == cs.total_elems()
    assert validate_schedule(sp).ok
    rt = IR.merge_messages(sp)
    canon = _canon(cs)
    for f in ("src", "dst", "elems", "round_ptr", "blk_ptr", "blk_ids"):
        assert np.array_equal(getattr(rt, f), getattr(canon, f)), (alg, f)
    for machine in (_machine(topo), Machine(topo=topo, cost=nvlink_ib_machine().cost)):
        for ported in (False, True):
            assert simulate(rt, machine, ported=ported).time_us == pytest.approx(
                simulate(cs, machine, ported=ported).time_us, rel=1e-12
            )
