"""Benchmark harness entry point: one section per paper table plus the
optimizer delta table, TPU projection, gradient-sync HLO comparison, and
the roofline summary.

Prints ``name,impl,k,c,sim_us,paper_us`` CSV rows (and roofline rows from
the dry-run artifacts when present); the paper section ends with the
``# optimizer:`` optimized-vs-paper delta lines.  ``--json FILE``
additionally writes every simulator cell as machine-readable
``{table, impl, k, c, sim_us, wall_s}`` records — OPT cells (adjacent
compaction, PR 2) and OPT2 cells (reordering + payload splitting, ISSUE 3)
carry ``{base_us, rounds_before, rounds_after, ported, passes}``, the
schedule optimizer's trajectory — so the perf story is tracked across PRs
(``BENCH_schedules.json`` by convention).  ``tools/bench_gate.py``
compares a fresh ``--json`` dump against the committed baseline and fails
CI on any >5% ``sim_us`` regression or disappeared cell.

ISSUE 7 observability: ``--trace``/``--trace-jsonl`` export the run's
flight-recorder spans (Chrome trace-event JSON / raw JSONL) and
``--metrics`` snapshots the metrics registry; any of them — and
``--deltas``, whose per-pass breakdown column is flight-recorder
sourced — enables the tracer for the run.

  PYTHONPATH=src python -m benchmarks.run [--skip-hlo] \
      [--only paper|tpu|hlo|roofline] [--json BENCH_schedules.json] \
      [--trace paper.trace.json] [--metrics paper.metrics.json]
"""

from __future__ import annotations

import argparse
import json
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only",
                    choices=["paper", "paper-opt", "tpu", "hlo", "roofline"],
                    default=None)
    ap.add_argument("--skip-hlo", action="store_true")
    ap.add_argument("--json", metavar="FILE", default=None,
                    help="write per-cell {table,impl,k,c,sim_us,wall_s} JSON")
    ap.add_argument("--deltas", metavar="FILE", default=None,
                    help="also write the OPT/OPT2/OPT3 optimized-vs-paper "
                    "delta table to FILE (CI uploads it as an artifact); "
                    "enables the tracer so the per-pass breakdown column "
                    "is flight-recorder sourced")
    ap.add_argument("--trace", metavar="FILE", default=None,
                    help="export the run's spans as a Chrome trace-event "
                    "file (load in Perfetto / chrome://tracing)")
    ap.add_argument("--trace-jsonl", metavar="FILE", default=None,
                    help="export the run's spans as raw JSONL, one record "
                    "per line")
    ap.add_argument("--metrics", metavar="FILE", default=None,
                    help="write the pipeline metrics snapshot (counters, "
                    "gauges, histograms) as JSON")
    args = ap.parse_args()

    trace_requested = bool(
        args.trace or args.trace_jsonl or args.metrics or args.deltas
    )
    if trace_requested:
        from repro.obs import trace as obs_trace
        obs_trace.enable()

    cells: list[dict] = []
    print("table,impl,k,c,sim_us,paper_us")
    if args.only in (None, "paper"):
        from benchmarks.paper_tables import (
            ALL_TABLES,
            csv_row,
            render_optimizer_deltas,
        )
        for fn in ALL_TABLES:
            for cell in fn():
                cells.append(cell)
                print(csv_row(cell), flush=True)
        delta_lines = render_optimizer_deltas(cells)
        for line in delta_lines:
            print(line, flush=True)
        if args.deltas:
            with open(args.deltas, "w") as f:
                f.write("\n".join(delta_lines) + "\n")
            print(f"# wrote optimizer delta table to {args.deltas}",
                  flush=True)
    elif args.only == "paper-opt":
        # ISSUE 5 CI satellite: one paper-scale (p=1152) alltoall OPT cell,
        # CHECK_TIMEOUT-bounded in tools/check.sh, so the optimizer's
        # scalability cannot silently regress in the fast job.
        from benchmarks.paper_tables import (
            csv_row,
            render_optimizer_deltas,
            table_paper_opt_smoke,
        )
        for cell in table_paper_opt_smoke():
            cells.append(cell)
            print(csv_row(cell), flush=True)
        for line in render_optimizer_deltas(cells):
            print(line, flush=True)
        if args.deltas:
            print(f"# optimizer deltas only written for --only paper; "
                  f"{args.deltas} not written", flush=True)
    elif args.deltas:
        # the OPT tables only run in the paper selection; stay loud rather
        # than silently skipping a requested output file
        print(f"# optimizer deltas only exist for --only paper; "
              f"{args.deltas} not written", flush=True)
    if args.only in (None, "tpu"):
        from benchmarks.collective_bench import tpu_projection
        from benchmarks.paper_tables import csv_row
        for cell in tpu_projection():
            cells.append(cell)
            print(csv_row(cell), flush=True)
    if args.only in (None, "hlo") and not args.skip_hlo:
        from benchmarks.collective_bench import grad_sync_hlo
        for row in grad_sync_hlo():
            print(row, flush=True)
    if args.only in (None, "roofline"):
        import os
        from benchmarks.roofline import csv_rows, roofline_table
        emitted = False
        # complete baseline table first, then the optimized cells
        for label, d in (("baseline", "experiments/dryrun_baseline"),
                         ("optimized", "experiments/dryrun")):
            if os.path.isdir(d):
                for row in csv_rows(roofline_table(d)):
                    print(f"{label}_{row}", flush=True)
                emitted = True
        if not emitted:
            print("roofline,,,no dry-run artifacts (run repro.launch.dryrun),,,")

    if args.json and not cells:
        # --only hlo/roofline collect no simulator cells; don't clobber a
        # previously written trajectory file with an empty one.
        print(f"# no simulator cells in this selection; {args.json} not written",
              flush=True)
    elif args.json:
        # OPT/OPT2/OPT3 cells additionally carry the optimizer trajectory:
        # the unoptimized baseline, the round delta, the port model the
        # cell was timed under, the optimizer's own wall-clock
        # (opt_wall_s — ISSUE 5 satellite; the gate stays on sim_us), and
        # the per-pass records.
        # DEG cells (ISSUE 6) carry the graceful-degradation context: the
        # healthy-machine time, the natively regenerated fallback where one
        # exists, and the fault fingerprint that keyed the repaired entry.
        # LB cells (ISSUE 9) carry the certificate context: the analytic
        # bound, the optimized time it certifies, and the round bound
        # (sim_us on an LB cell IS gap_vs_lb — the gated ratio).
        opt_keys = ("base_us", "rounds_before", "rounds_after", "ported",
                    "opt_wall_s", "passes",
                    "healthy_us", "native_us", "scenario", "fingerprint",
                    "lb_us", "opt_us", "rounds_lb", "gap_vs_lb")
        payload = {
            "generated_at": time.strftime("%Y-%m-%dT%H:%M:%S"),
            "cells": [
                {
                    "table": c["table"],
                    "impl": c["impl"],
                    "k": c["k"],
                    "c": c["c"],
                    "sim_us": c["sim_us"],
                    "wall_s": c["wall_s"],
                    **{k: c[k] for k in opt_keys if k in c},
                }
                for c in cells
            ],
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {len(payload['cells'])} cells to {args.json}", flush=True)

    if trace_requested:
        from repro.obs import metrics as obs_metrics
        from repro.obs.trace import TRACER
        if args.trace:
            TRACER.export_chrome(args.trace)
            print(f"# wrote Chrome trace ({TRACER.total} spans, "
                  f"{TRACER.dropped} dropped) to {args.trace}", flush=True)
        if args.trace_jsonl:
            TRACER.export_jsonl(args.trace_jsonl)
            print(f"# wrote trace JSONL to {args.trace_jsonl}", flush=True)
        if args.metrics:
            with open(args.metrics, "w") as f:
                json.dump(obs_metrics.snapshot(), f, indent=1, default=str)
            print(f"# wrote metrics snapshot to {args.metrics}", flush=True)


if __name__ == "__main__":
    main()
