"""Synthetic sharded data pipeline.

Deterministic token streams keyed by (seed, step, shard): every data-parallel
host generates exactly its shard of the global batch with no coordination —
the property that makes restart/elastic-rescale trivial (the stream is a
pure function of the step counter, so resuming from checkpoint step k
reproduces the exact batch sequence, and a re-meshed job keeps data
consistency by construction).

A background prefetch thread keeps ``depth`` batches ready.
"""

from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig

__all__ = ["SyntheticLM", "Prefetcher", "make_batch"]


def make_batch(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0,
               step: int = 0) -> dict:
    """One deterministic global batch for ``cfg`` (token LMs get tokens +
    next-token labels; the VLM stub gets embeddings + labels)."""
    rng = np.random.Generator(np.random.Philox(key=seed, counter=[0, 0, 0, step]))
    V = cfg.vocab_size
    if cfg.embed_inputs:
        shape = (batch, seq + 1, cfg.num_codebooks) if cfg.num_codebooks > 1 \
            else (batch, seq + 1)
        toks = rng.integers(0, V, shape, dtype=np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    emb = rng.standard_normal((batch, seq, cfg.d_model), dtype=np.float32)
    labels = rng.integers(0, V, (batch, seq), dtype=np.int32)
    return {"embeds": emb, "labels": labels}


class SyntheticLM:
    """Iterator over (step, batch) pairs, resumable at any step.

    ``corpus_size=None`` streams fresh i.i.d. noise (throughput testing);
    ``corpus_size=k`` cycles over k fixed batches (a learnable target for
    convergence tests and the examples), still a pure function of step."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0,
                 start_step: int = 0, corpus_size: int | None = None):
        self.cfg, self.batch, self.seq, self.seed = cfg, batch, seq, seed
        self.step = start_step
        self.corpus_size = corpus_size

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        data_step = self.step if self.corpus_size is None \
            else self.step % self.corpus_size
        b = make_batch(self.cfg, self.batch, self.seq, seed=self.seed,
                       step=data_step)
        out = (self.step, b)
        self.step += 1
        return out


class Prefetcher:
    """Background-thread prefetch of an iterator (depth-bounded)."""

    def __init__(self, it, depth: int = 2):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: Exception | None = None

        def work():
            try:
                for item in it:
                    self._q.put(item)
            except Exception as e:
                self._err = e
            finally:
                self._q.put(self._done)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err:
                raise self._err
            raise StopIteration
        return item
