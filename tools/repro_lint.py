"""Repo-discipline lint: AST checks for the invariants ruff cannot see.

Three rules, each born from a real bug class in this repo's history:

``L001`` **lock discipline** (the PR-7 race-detector check).  In any
    module that owns a module-level lock (a top-level ``NAME =
    threading.Lock()`` / ``RLock()`` assignment), every *write* to
    module-level shared state — ``global``-declared rebinding or
    augmented assignment, subscript stores/deletes, and mutating method
    calls (``update``/``pop``/``append``/...) on a module-level name —
    must sit lexically inside a ``with <that lock>:`` block.  PR 7 fixed
    exactly this: cache-counter ``+= 1`` races outside ``_LOCK``.

``L002`` **span closure**.  Every ``sp = TRACER.start(...)`` must reach a
    ``TRACER.finish(sp, ...)`` (or ``sp.finish(...)``) on *all* paths out
    of the function.  Accepted shapes: a ``try/finally`` whose
    ``finally`` closes the span, or the repo's documented single-boundary
    pattern — an ``except`` handler that closes the span and re-raises,
    *plus* a normal-path close.  A straight-line ``start ... finish``
    leaks the span whenever the code in between raises, which corrupts
    the flight recorder's open-span stack for every later span.

``L003`` **pass annotation**.  Every scheduling-pass class (a class
    defining ``apply(self, cs)``) must declare ``recipe_safe`` — either
    as a class attribute or as ``self.recipe_safe = ...`` in
    ``__init__`` — because the schedule cache's recipe layer replays
    passes by name and silently assumes unannotated passes are safe.

A violation can be waived on its own line with a ``# lint: ok`` comment
(optionally scoped, e.g. ``# lint: ok[L001]``) when the code is correct
for a reason the AST cannot express; say why in a neighbouring comment.

Run as ``python -m tools.repro_lint [paths...]`` (defaults to the repo's
lint surface: ``src/repro``, ``tools``, ``benchmarks``).  Exits non-zero
on any violation.  ``lint_source(text, filename)`` is the library entry
point the self-tests drive with fixture snippets.
"""

from __future__ import annotations

import argparse
import ast
import dataclasses
import os
import sys

__all__ = ["Violation", "lint_source", "lint_file", "main", "DEFAULT_PATHS"]

DEFAULT_PATHS = ("src/repro", "tools", "benchmarks")

#: Container-mutating method names treated as writes under L001.
_MUTATORS = frozenset({
    "update", "clear", "pop", "popitem", "setdefault",
    "append", "extend", "insert", "remove", "discard", "add",
})


@dataclasses.dataclass(frozen=True)
class Violation:
    path: str
    line: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.message}"


def _is_lock_ctor(node: ast.AST) -> bool:
    """``threading.Lock()`` / ``threading.RLock()`` (any attribute chain
    ending in Lock/RLock, or a bare ``Lock()``)."""
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    name = f.attr if isinstance(f, ast.Attribute) else (
        f.id if isinstance(f, ast.Name) else "")
    return name in ("Lock", "RLock")


def _assign_names(node: ast.AST) -> list[str]:
    """Simple-Name targets of a top-level Assign/AnnAssign."""
    out: list[str] = []
    if isinstance(node, ast.Assign):
        for t in node.targets:
            if isinstance(t, ast.Name):
                out.append(t.id)
    elif isinstance(node, ast.AnnAssign) and isinstance(node.target, ast.Name):
        out.append(node.target.id)
    return out


class _Parents(ast.NodeVisitor):
    """Annotate every node with a ``_parent`` backlink."""

    def generic_visit(self, node: ast.AST) -> None:
        for child in ast.iter_child_nodes(node):
            child._parent = node  # type: ignore[attr-defined]
        super().generic_visit(node)


def _ancestors(node: ast.AST):
    cur = getattr(node, "_parent", None)
    while cur is not None:
        yield cur
        cur = getattr(cur, "_parent", None)


def _under_lock(node: ast.AST, locks: frozenset[str]) -> bool:
    for anc in _ancestors(node):
        if isinstance(anc, ast.With):
            for item in anc.items:
                ctx = item.context_expr
                if isinstance(ctx, ast.Name) and ctx.id in locks:
                    return True
    return False


def _enclosing_function(node: ast.AST):
    for anc in _ancestors(node):
        if isinstance(anc, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return anc
    return None


# --------------------------------------------------------------------------
# L001: lock discipline
# --------------------------------------------------------------------------

def _check_locks(tree: ast.Module, path: str, out: list[Violation]) -> None:
    locks, shared = set(), set()
    for stmt in tree.body:
        names = _assign_names(stmt)
        value = getattr(stmt, "value", None)
        if names and value is not None and _is_lock_ctor(value):
            locks.update(names)
        else:
            shared.update(names)
    if not locks:
        return  # module owns no lock: nothing to enforce
    locks_f = frozenset(locks)
    shared -= locks

    # names a function declares ``global``: rebinding them is a write
    global_decls: dict[ast.AST, set[str]] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Global):
            fn = _enclosing_function(node)
            if fn is not None:
                global_decls.setdefault(fn, set()).update(
                    n for n in node.names if n in shared)

    def flag(node: ast.AST, name: str, what: str) -> None:
        if not _under_lock(node, locks_f):
            out.append(Violation(
                path, node.lineno, "L001",
                f"{what} of module-level shared state '{name}' outside "
                f"'with {sorted(locks_f)[0]}' — the PR-7 racy-counter "
                f"pattern"))

    for node in ast.walk(tree):
        fn = _enclosing_function(node)
        if fn is None:
            continue  # module-level initialization is the definition
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if (isinstance(t, ast.Name)
                        and t.id in global_decls.get(fn, ())):
                    flag(node, t.id, "rebinding")
                elif (isinstance(t, ast.Subscript)
                        and isinstance(t.value, ast.Name)
                        and t.value.id in shared):
                    flag(node, t.value.id, "subscript write")
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                if (isinstance(t, ast.Subscript)
                        and isinstance(t.value, ast.Name)
                        and t.value.id in shared):
                    flag(node, t.value.id, "subscript delete")
        elif isinstance(node, ast.Call):
            f = node.func
            if (isinstance(f, ast.Attribute) and f.attr in _MUTATORS
                    and isinstance(f.value, ast.Name)
                    and f.value.id in shared):
                flag(node, f.value.id, f".{f.attr}() mutation")


# --------------------------------------------------------------------------
# L002: span closure
# --------------------------------------------------------------------------

def _span_start_var(stmt: ast.AST) -> str | None:
    """Name bound by ``v = TRACER.start(...)`` or the guarded
    ``v = TRACER.start(...) if TRACER else None`` idiom."""
    if not (isinstance(stmt, ast.Assign) and len(stmt.targets) == 1
            and isinstance(stmt.targets[0], ast.Name)):
        return None
    value = stmt.value
    if isinstance(value, ast.IfExp):
        value = value.body
    if (isinstance(value, ast.Call)
            and isinstance(value.func, ast.Attribute)
            and value.func.attr == "start"
            and isinstance(value.func.value, ast.Name)
            and value.func.value.id == "TRACER"):
        return stmt.targets[0].id
    return None


def _is_span_close(node: ast.AST, var: str) -> bool:
    if not isinstance(node, ast.Call):
        return False
    f = node.func
    if isinstance(f, ast.Attribute) and f.attr == "finish":
        if isinstance(f.value, ast.Name) and f.value.id == var:
            return True  # sp.finish(...)
        if (isinstance(f.value, ast.Name) and f.value.id == "TRACER"
                and node.args and isinstance(node.args[0], ast.Name)
                and node.args[0].id == var):
            return True  # TRACER.finish(sp, ...)
    return False


def _check_spans(tree: ast.Module, path: str, out: list[Violation]) -> None:
    for fn in ast.walk(tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        starts: list[tuple[str, int]] = []
        for node in ast.walk(fn):
            if _enclosing_function(node) is not fn:
                continue  # nested defs audit their own spans
            var = _span_start_var(node)
            if var is not None:
                starts.append((var, node.lineno))
        for var, line in starts:
            finally_ok = handler_ok = normal_ok = False
            for node in ast.walk(fn):
                if _enclosing_function(node) is not fn:
                    continue
                if isinstance(node, ast.Try):
                    for fin in node.finalbody:
                        if any(_is_span_close(n, var)
                               for n in ast.walk(fin)):
                            finally_ok = True
                elif isinstance(node, ast.ExceptHandler):
                    closes = any(_is_span_close(n, var)
                                 for n in ast.walk(node))
                    raises = any(isinstance(n, ast.Raise)
                                 for n in ast.walk(node))
                    if closes and raises:
                        handler_ok = True
                elif _is_span_close(node, var):
                    if not any(isinstance(a, ast.ExceptHandler)
                               for a in _ancestors(node)):
                        normal_ok = True
            if not (finally_ok or (handler_ok and normal_ok)):
                out.append(Violation(
                    path, line, "L002",
                    f"span '{var}' started in {fn.name}() is not closed "
                    f"on all paths: close it in a 'finally', or use the "
                    f"single-boundary pattern (an 'except' that finishes "
                    f"with outcome=\"error\" and re-raises, plus a "
                    f"normal-path finish)"))


# --------------------------------------------------------------------------
# L003: pass annotation
# --------------------------------------------------------------------------

def _check_passes(tree: ast.Module, path: str, out: list[Violation]) -> None:
    for cls in ast.walk(tree):
        if not isinstance(cls, ast.ClassDef):
            continue
        is_pass = any(
            isinstance(m, ast.FunctionDef) and m.name == "apply"
            and len(m.args.args) >= 2
            for m in cls.body)
        if not is_pass:
            continue
        declared = any(
            n == "recipe_safe"
            for stmt in cls.body for n in _assign_names(stmt))
        if not declared:
            for m in cls.body:
                if isinstance(m, ast.FunctionDef) and m.name == "__init__":
                    for node in ast.walk(m):
                        if (isinstance(node, ast.Assign)
                                and any(isinstance(t, ast.Attribute)
                                        and t.attr == "recipe_safe"
                                        and isinstance(t.value, ast.Name)
                                        and t.value.id == "self"
                                        for t in node.targets)):
                            declared = True
        if not declared:
            out.append(Violation(
                path, cls.lineno, "L003",
                f"pass class '{cls.name}' defines apply() but does not "
                f"declare recipe_safe — the schedule cache's recipe "
                f"layer needs it to know whether the rewrite replays "
                f"under a different payload"))


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------

def lint_source(source: str, path: str = "<string>") -> list[Violation]:
    """Lint one module's source text; returns surviving violations."""
    tree = ast.parse(source, filename=path)
    _Parents().visit(tree)
    out: list[Violation] = []
    _check_locks(tree, path, out)
    _check_spans(tree, path, out)
    _check_passes(tree, path, out)
    lines = source.splitlines()
    kept = []
    for v in out:
        text = lines[v.line - 1] if v.line - 1 < len(lines) else ""
        if "# lint: ok" in text:
            tag = text.split("# lint: ok", 1)[1]
            if not tag.startswith("[") or f"[{v.rule}]" in "# lint: ok" + tag:
                continue
        kept.append(v)
    return kept


def lint_file(path: str) -> list[Violation]:
    with open(path, encoding="utf-8") as fh:
        return lint_source(fh.read(), path)


def _iter_py(paths) -> list[str]:
    found: list[str] = []
    for p in paths:
        if os.path.isfile(p):
            found.append(p)
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = [d for d in dirs
                       if d not in ("__pycache__", ".git")]
            found.extend(os.path.join(root, f)
                         for f in files if f.endswith(".py"))
    return sorted(found)


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        description="repo-discipline lint (locks, spans, pass annotations)")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS))
    args = ap.parse_args(argv)
    total = 0
    for path in _iter_py(args.paths):
        try:
            violations = lint_file(path)
        except SyntaxError as exc:
            print(f"{path}:{exc.lineno}: E999 syntax error: {exc.msg}")
            total += 1
            continue
        for v in violations:
            print(v)
        total += len(violations)
    if total:
        print(f"repro_lint: {total} violation(s)")
        return 1
    print("repro_lint: clean")
    return 0


if __name__ == "__main__":
    sys.exit(main())
